"""Serving engine invariants: deterministic bucketing, per-tier batching,
executable-cache behavior on replayed traffic, and the numerics contract —
bucket-batched outputs are bit-identical to the unbatched per-request path
(padded rows and batch-mates can never change a request's tokens)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import AnalogConfig, analog_dot
from repro.core.analog import collapse_keys, raw_key
from repro.models import init_energy_tree, init_params, lm
from repro.models.config import ModelConfig
from repro.serving import (
    ServingEngine,
    TierScheduler,
    bucket_shape,
    next_bucket,
    pad_to_bucket,
)
from repro.serving.bucketing import DEFAULT_BATCH_BUCKETS, DEFAULT_SEQ_BUCKETS
from repro.serving.scheduler import Request

KEY = jax.random.PRNGKey(0)
MODEL = ModelConfig(
    name="serve-test", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=1, d_ff=64, vocab_size=128, attn_q_chunk=16, attn_kv_chunk=16,
    loss_chunk=32, dtype="float32",
)
ENERGY_AJ = 20.0
SB = 32  # single seq bucket for the engine tests


@pytest.fixture(scope="module")
def env():
    params = init_params(KEY, MODEL)
    energies = init_energy_tree(MODEL, ENERGY_AJ)
    engine = ServingEngine(
        params, MODEL, analog_cfg=AnalogConfig.shot(), energies=energies,
        max_gen=8, max_batch=4, max_wait=1.0,
        batch_buckets=(1, 2, 4), seq_buckets=(SB,),
    )
    return dict(params=params, energies=energies, engine=engine)


# --------------------------------------------------------------------------
# bucketing: deterministic, total, shape-correct
# --------------------------------------------------------------------------


def test_bucket_selection_deterministic():
    buckets = (32, 64, 128)
    for v in (1, 31, 32, 33, 64, 100, 128):
        assert next_bucket(v, buckets) == next_bucket(v, buckets)
        assert next_bucket(v, buckets) >= v
    assert next_bucket(33, buckets) == 64
    assert bucket_shape(3, 40, batch_buckets=(1, 2, 4), seq_buckets=buckets) == (4, 64)
    with pytest.raises(ValueError):
        next_bucket(129, buckets)
    with pytest.raises(ValueError):
        next_bucket(0, buckets)


def test_pad_to_bucket_shapes_and_lengths():
    prompts = [np.arange(5), np.arange(9)]
    tokens, lengths = pad_to_bucket(prompts, (4, 16), pad_id=0)
    assert tokens.shape == (4, 16) and lengths.shape == (4,)
    assert lengths.tolist() == [5, 9, 0, 0]  # length 0 marks batch-pad rows
    assert tokens[0, :5].tolist() == list(range(5))
    assert (tokens[0, 5:] == 0).all() and (tokens[2:] == 0).all()
    with pytest.raises(ValueError):
        pad_to_bucket([np.arange(20)], (1, 16))
    with pytest.raises(ValueError, match="empty"):
        pad_to_bucket([np.arange(0)], (1, 16))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 8), seed=st.integers(0, 2**16))
def test_bucketing_round_trip_property(n, seed):
    """pad_to_bucket/next_bucket round-trip on the default ladders: every
    real prompt's tokens and length are recovered exactly, buckets are the
    minimal ladder entries that fit, and pad rows (length 0, all pad_id) can
    never be mistaken for a real row (real lengths are >= 1)."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(1, max(DEFAULT_SEQ_BUCKETS) + 1, n)
    prompts = [rng.integers(1, 1000, L).astype(np.int32) for L in lens]
    bb, sb = bucket_shape(n, int(lens.max()))
    for value, bucket, ladder in (
        (n, bb, DEFAULT_BATCH_BUCKETS), (int(lens.max()), sb, DEFAULT_SEQ_BUCKETS)
    ):
        assert bucket in ladder and bucket >= value
        assert not any(value <= b < bucket for b in ladder)  # minimal fit
    tokens, lengths = pad_to_bucket(prompts, (bb, sb), pad_id=0)
    assert tokens.shape == (bb, sb) and lengths.shape == (bb,)
    for i, p in enumerate(prompts):
        assert lengths[i] == p.size  # lengths recovered exactly
        np.testing.assert_array_equal(tokens[i, : p.size], p)
        assert (tokens[i, p.size :] == 0).all()
    assert (lengths[:n] >= 1).all()
    assert (lengths[n:] == 0).all() and (tokens[n:] == 0).all()


# --------------------------------------------------------------------------
# scheduler: per-tier batches, max-wait deadline, determinism
# --------------------------------------------------------------------------


def _req(uid, length, k, arrival):
    return Request(uid=uid, tokens=np.zeros(length, np.int32), n_repeats=k,
                   arrival=arrival)


def test_mixed_k_queue_produces_per_tier_batches():
    sch = TierScheduler(max_batch=2, max_wait=10.0, seq_buckets=(32,))
    for uid, k in enumerate([1, 2, 1, 2, 4]):
        sch.submit(_req(uid, 8, k, arrival=0.0))
    batches = sch.pop_ready(now=0.0)  # only full groups dispatch
    assert [[r.uid for r in b] for b in batches] == [[0, 2], [1, 3]]
    for b in batches:
        assert len({r.n_repeats for r in b}) == 1  # never mixes tiers
    assert sch.n_pending == 1  # the lone K=4 request waits


def test_max_wait_deadline_flushes_low_traffic_tier():
    sch = TierScheduler(max_batch=4, max_wait=5.0, seq_buckets=(32,))
    sch.submit(_req(0, 8, 4, arrival=0.0))
    assert sch.pop_ready(now=4.9) == []  # under deadline: keep waiting
    batches = sch.pop_ready(now=5.0)
    assert [[r.uid for r in b] for b in batches] == [[0]]
    assert sch.n_pending == 0


def test_scheduler_replay_deterministic():
    def run():
        sch = TierScheduler(max_batch=2, max_wait=1.0, seq_buckets=(16, 32))
        out = []
        for uid, (length, k, t) in enumerate(
            [(8, 1, 0.0), (20, 1, 0.1), (8, 2, 0.2), (9, 1, 0.3), (30, 2, 0.4)]
        ):
            sch.submit(_req(uid, length, k, arrival=t))
            out += [[r.uid for r in b] for b in sch.pop_ready(now=t)]
        out += [[r.uid for r in b] for b in sch.flush()]
        return out

    assert run() == run()


# --------------------------------------------------------------------------
# stacked per-request keys: the noise-isolation primitive
# --------------------------------------------------------------------------


def test_stacked_keys_match_unbatched_analog_dot():
    cfg = AnalogConfig.shot()
    keys = jnp.stack([jax.random.fold_in(KEY, i) for i in range(3)])
    x = jax.random.normal(KEY, (3, 8, 16))
    w = jax.random.normal(jax.random.fold_in(KEY, 7), (16, 12)) * 0.1
    e = jnp.asarray(5.0)
    y = analog_dot(x, w, cfg=cfg, energy=e, key=keys, n_repeats=2)
    for i in range(3):
        solo = analog_dot(x[i], w, cfg=cfg, energy=e, key=keys[i], n_repeats=2)
        np.testing.assert_array_equal(np.asarray(y[i]), np.asarray(solo))
    # rows are invariant to their batch-mates
    y_perm = analog_dot(x[::-1], w, cfg=cfg, energy=e, key=keys[::-1], n_repeats=2)
    np.testing.assert_array_equal(np.asarray(y_perm[::-1]), np.asarray(y))


def test_collapse_keys_excludes_pad_rows():
    """Regression: the batch-level MoE noise key must depend only on the
    REAL requests in a bucket batch. Pad rows (length 0) fold the XOR
    identity, so the same real traffic collapses to the same key at any
    batch-pad count — and regardless of what keys the pad rows carry."""
    real = [raw_key(jax.random.fold_in(KEY, i)) for i in range(3)]
    pad = raw_key(jax.random.PRNGKey(0))
    f_real = collapse_keys(jnp.stack(real))
    for n_pads, pad_key in ((1, pad), (3, pad), (2, raw_key(jax.random.PRNGKey(99)))):
        stacked = jnp.stack(real + [pad_key] * n_pads)
        valid = jnp.asarray([True] * 3 + [False] * n_pads)
        np.testing.assert_array_equal(
            np.asarray(collapse_keys(stacked, valid)), np.asarray(f_real)
        )
    # without pad rows the mask is a no-op; single keys pass through
    np.testing.assert_array_equal(
        np.asarray(collapse_keys(jnp.stack(real), jnp.ones(3, bool))),
        np.asarray(f_real),
    )
    np.testing.assert_array_equal(
        np.asarray(collapse_keys(real[0])), np.asarray(real[0])
    )


def test_moe_expert_noise_ignores_pad_rows():
    """End-to-end regression for the pad-key fold bug: serving the same two
    real requests in a bucket with batch-pad rows, the pad rows' PRNG keys
    (previously XOR-folded into the batch-level expert stream) must not
    change the real rows' tokens — prefill and decode."""
    cfg = FAMILY_CONFIGS["moe"]
    params = init_params(KEY, cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    shot = AnalogConfig.shot()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, L) for L in (5, 9)]
    toks = np.zeros((4, 16), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
    lengths = jnp.asarray([5, 9, 0, 0], jnp.int32)

    def run(pad_seed):
        keys = jnp.stack(
            [raw_key(jax.random.fold_in(KEY, i)) for i in range(2)]
            + [raw_key(jax.random.PRNGKey(pad_seed))] * 2
        )
        analog = lm.AnalogSpec(cfg=shot, energies=energies, key=keys)
        cache, h_last = lm.prefill(
            params, {"tokens": jnp.asarray(toks)}, cfg, analog=analog,
            cache_len=20, lengths=lengths,
        )
        tok = jnp.argmax(lm.logits_last(params, h_last, cfg)[:, 0, 0], axis=-1)
        outs = [np.asarray(tok)]
        for t in range(3):
            pos = lengths + t
            step = lm.AnalogSpec(
                cfg=shot, energies=energies,
                key=jax.vmap(jax.random.fold_in)(keys, pos),
            )
            logits, cache = lm.decode_step(
                params, cache, {"tokens": tok[:, None].astype(jnp.int32)},
                pos, cfg, analog=step, lengths=lengths,
            )
            tok = jnp.argmax(logits[:, 0, 0], axis=-1)
            outs.append(np.asarray(tok))
        return np.stack(outs, axis=1)

    a, b = run(0), run(12345)
    np.testing.assert_array_equal(a[:2], b[:2])


# --------------------------------------------------------------------------
# engine: batching is invisible to each request's numerics
# --------------------------------------------------------------------------

PROMPT_LENS = (7, 19, 28)


def _prompts_and_keys():
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, MODEL.vocab_size, L) for L in PROMPT_LENS]
    keys = [jax.random.fold_in(jax.random.PRNGKey(5), i) for i in range(len(prompts))]
    return prompts, keys


def test_padded_rows_and_batchmates_dont_change_outputs(env):
    """3 requests share a (4, 32) bucket (1 padded row): every request's
    tokens must equal its solo run at batch bucket 1."""
    eng = env["engine"]
    prompts, keys = _prompts_and_keys()

    uids = [
        eng.submit(p, n_repeats=2, max_new_tokens=6, key=k, now=0.0)
        for p, k in zip(prompts, keys)
    ]
    padded_before = eng.stats["padded_rows"]
    batched = eng.flush()
    assert eng.stats["padded_rows"] - padded_before == 1  # bb=4 held 3 reqs

    for uid, p, k in zip(uids, prompts, keys):
        solo_uid = eng.submit(p, n_repeats=2, max_new_tokens=6, key=k, now=0.0)
        solo = eng.flush()[solo_uid]
        np.testing.assert_array_equal(batched[uid], solo)


def test_engine_matches_unbatched_analog_dot_path(env):
    """Engine tokens == a from-scratch, unjitted prefill/decode loop through
    the plain analog_dot path (no engine, no AOT, no batching)."""
    eng, params, energies = env["engine"], env["params"], env["energies"]
    prompts, keys = _prompts_and_keys()
    gen = 4

    uids = [
        eng.submit(p, n_repeats=2, max_new_tokens=gen, key=k, now=0.0)
        for p, k in zip(prompts, keys)
    ]
    got = eng.flush()

    shot = AnalogConfig.shot()
    for uid, prompt, key in zip(uids, prompts, keys):
        L = len(prompt)
        tokens = np.zeros((1, SB), np.int32)
        tokens[0, :L] = prompt
        lengths = jnp.asarray([L], jnp.int32)
        skeys = jnp.stack([key])  # (1, 2): the stacked per-request form
        analog = lm.AnalogSpec(cfg=shot, energies=energies, key=skeys, n_repeats=2)
        cache, h_last = lm.prefill(
            params, {"tokens": jnp.asarray(tokens)}, MODEL,
            analog=analog, cache_len=SB + eng.max_gen, lengths=lengths,
        )
        tok = jnp.argmax(lm.logits_last(params, h_last, MODEL)[:, 0, 0], axis=-1)
        toks = [int(tok[0])]
        for t in range(gen - 1):
            pos = lengths + t
            analog_t = lm.AnalogSpec(
                cfg=shot, energies=energies,
                key=jax.vmap(jax.random.fold_in)(skeys, pos), n_repeats=2,
            )
            logits, cache = lm.decode_step(
                params, cache, {"tokens": tok[:, None].astype(jnp.int32)},
                pos, MODEL, analog=analog_t,
            )
            tok = jnp.argmax(logits[:, 0, 0], axis=-1)
            toks.append(int(tok[0]))
        np.testing.assert_array_equal(got[uid], np.asarray(toks, np.int32))


def test_executable_cache_hits_on_replayed_trace(env):
    """Replaying a mixed-tier trace after warmup: zero misses, zero retraces,
    and hits == 2 executables (prefill+decode) per dispatched batch."""
    eng = env["engine"]
    prompts, keys = _prompts_and_keys()

    def replay():
        for p, k in zip(prompts, keys):
            eng.submit(p, n_repeats=2, max_new_tokens=4, key=k, now=0.0)
        eng.submit(prompts[0], n_repeats=1, max_new_tokens=4, key=keys[0], now=0.0)
        return eng.flush()

    replay()  # warmup: compiles whatever earlier tests haven't
    eng.exe_cache.reset_stats()
    traces_before = eng.trace_count
    batches_before = eng.stats["batches"]
    out = replay()
    assert len(out) == 4
    n_batches = eng.stats["batches"] - batches_before
    assert n_batches == 2  # one K=2 batch, one K=1 batch: tiers never mix
    stats = eng.exe_cache.stats()
    assert stats["misses"] == 0
    assert stats["hits"] == 2 * n_batches
    assert eng.trace_count == traces_before  # zero steady-state retraces


@pytest.mark.parametrize("arch", ["granite-3-8b", "recurrentgemma-2b"])
def test_per_row_positions_match_scalar_pos(arch):
    """decode_step with pos=(B,) full of p must equal pos=p bit-exactly —
    including the windowed ring-cache path (griffin local attention)."""
    import dataclasses

    from repro.configs import get_smoke_config

    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params = init_params(KEY, cfg)
    b, t = 2, 16
    toks = jax.random.randint(KEY, (b, t + 1), 0, cfg.vocab_size)
    cache, _ = lm.prefill(params, {"tokens": toks[:, :t]}, cfg, cache_len=t + 1)
    dec = {"tokens": toks[:, t:]}
    logits_s, cache_s = lm.decode_step(params, cache, dec, t, cfg)
    logits_v, cache_v = lm.decode_step(
        params, cache, dec, jnp.full((b,), t, jnp.int32), cfg
    )
    np.testing.assert_array_equal(np.asarray(logits_s), np.asarray(logits_v))
    for a, bb in zip(jax.tree.leaves(cache_s), jax.tree.leaves(cache_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


# --------------------------------------------------------------------------
# length-aware prefill: every family serves, padding is inert
# --------------------------------------------------------------------------

_BASE = dict(
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
    vocab_size=128, attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32,
    dtype="float32",
)
#: one config per stateful family; windows/ratios sized so a (4, 32) bucket
#: exercises ring wraparound, recurrent pad suffixes, and expert dispatch.
#: moe capacity_factor = n_experts / top_k: no-drop serving, the regime in
#: which per-request bit-identity is well-defined for GShard routing.
FAMILY_CONFIGS = {
    "dense": ModelConfig(name="serve-dense", family="dense", d_ff=64, **_BASE),
    "windowed": ModelConfig(
        name="serve-win", family="dense", d_ff=64, sliding_window=8, **_BASE
    ),
    "griffin": ModelConfig(
        name="serve-griffin", family="griffin", n_layers=3, d_model=32,
        n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64, vocab_size=128,
        rnn_width=32, conv_width=4, local_window=8, attn_q_chunk=16,
        attn_kv_chunk=16, loss_chunk=32, dtype="float32",
    ),
    "xlstm": ModelConfig(
        name="serve-xlstm", family="xlstm", d_ff=0, slstm_ratio=2,
        n_kv_heads=2, **{k: v for k, v in _BASE.items() if k != "n_kv_heads"}
    ),
    "moe": ModelConfig(
        name="serve-moe", family="moe", d_ff=64, n_experts=4, top_k=2,
        moe_every=1, capacity_factor=2.0, moe_group_size=64, **_BASE
    ),
}


def _solo_tokens(params, cfg, prompt, gen):
    """Greedy tokens from a from-scratch UNPADDED run: exact-length prefill
    at batch 1, no bucket, no engine — the ground truth padding must never
    perturb."""
    L = len(prompt)
    tokens = jnp.asarray(prompt, jnp.int32)[None, :]
    cache, h_last = lm.prefill(params, {"tokens": tokens}, cfg, cache_len=L + gen)
    tok = jnp.argmax(lm.logits_last(params, h_last, cfg)[:, 0, 0], axis=-1)
    toks = [int(tok[0])]
    for t in range(gen - 1):
        logits, cache = lm.decode_step(
            params, cache, {"tokens": tok[:, None].astype(jnp.int32)},
            jnp.asarray([L + t], jnp.int32), cfg,
        )
        tok = jnp.argmax(logits[:, 0, 0], axis=-1)
        toks.append(int(tok[0]))
    return np.asarray(toks, np.int32)


@pytest.mark.parametrize("family", sorted(FAMILY_CONFIGS))
def test_family_solo_vs_batched_equivalence(family):
    """The acceptance contract of length-aware prefill: for EVERY family, a
    request's generated tokens in a padded bucket batch (pad rows + shorter
    batch-mates) equal its solo unpadded run."""
    cfg = FAMILY_CONFIGS[family]
    params = init_params(KEY, cfg)
    eng = ServingEngine(
        params, cfg, max_gen=8, max_batch=4, max_wait=1.0,
        batch_buckets=(1, 2, 4), seq_buckets=(SB,),
    )
    prompts, _ = _prompts_and_keys()
    gen = 4
    uids = [eng.submit(p, max_new_tokens=gen, now=0.0) for p in prompts]
    padded_before = eng.stats["padded_rows"]
    batched = eng.flush()
    assert eng.stats["padded_rows"] - padded_before == 1  # bb=4 held 3 reqs
    for uid, p in zip(uids, prompts):
        np.testing.assert_array_equal(batched[uid], _solo_tokens(params, cfg, p, gen))


@pytest.mark.parametrize("family", ["windowed", "griffin", "xlstm"])
def test_family_analog_batchmates_dont_change_outputs(family):
    """Analog serving of the stateful families: per-request noise streams +
    length-aware prefill make tokens bit-identical whether a request shares
    its bucket with batch-mates or runs alone at the same seq bucket. (MoE is
    excluded: expert capacity buffers mix requests, so its expert sites draw
    a batch-level stream — see AnalogHook.batched.)"""
    cfg = FAMILY_CONFIGS[family]
    params = init_params(KEY, cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    eng = ServingEngine(
        params, cfg, analog_cfg=AnalogConfig.shot(), energies=energies,
        max_gen=8, max_batch=4, max_wait=1.0,
        batch_buckets=(1, 2, 4), seq_buckets=(SB,),
    )
    prompts, keys = _prompts_and_keys()
    uids = [
        eng.submit(p, n_repeats=2, max_new_tokens=4, key=k, now=0.0)
        for p, k in zip(prompts, keys)
    ]
    batched = eng.flush()
    for uid, p, k in zip(uids, prompts, keys):
        solo_uid = eng.submit(p, n_repeats=2, max_new_tokens=4, key=k, now=0.0)
        solo = eng.flush()[solo_uid]
        np.testing.assert_array_equal(batched[uid], solo)


def test_window_larger_than_cache_stays_linear():
    """The real recurrentgemma serving regime: local_window (2048) exceeds
    cache_len (sb + max_gen). The ring is then sized to cache_len and never
    wraps — length-aware prefill must keep slot == position there, matching
    init_cache's shapes and the solo unpadded run."""
    import dataclasses

    cfg = dataclasses.replace(FAMILY_CONFIGS["griffin"], local_window=64)
    params = init_params(KEY, cfg)
    T, gen = 16, 4
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, L) for L in (5, 13)]
    toks = np.zeros((2, T), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
    lengths = jnp.asarray([len(p) for p in prompts], jnp.int32)
    cache, h_last = lm.prefill(
        params, {"tokens": jnp.asarray(toks)}, cfg, cache_len=T + gen,
        lengths=lengths,
    )
    want = jax.tree.map(
        lambda a: a.shape, jax.eval_shape(lambda: lm.init_cache(cfg, 2, T + gen))
    )
    assert jax.tree.map(lambda a: a.shape, cache) == want
    tok = jnp.argmax(lm.logits_last(params, h_last, cfg)[:, 0, 0], axis=-1)
    out = [np.asarray(tok)]
    for t in range(gen - 1):
        logits, cache = lm.decode_step(
            params, cache, {"tokens": tok[:, None].astype(jnp.int32)},
            lengths + t, cfg, lengths=lengths,
        )
        tok = jnp.argmax(logits[:, 0, 0], axis=-1)
        out.append(np.asarray(tok))
    batched = np.stack(out, axis=1)
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(batched[i], _solo_tokens(params, cfg, p, gen))


def test_seq_bucket_boundary_prompt():
    """A prompt exactly filling the seq bucket, decoding the full max_gen,
    must stay inside the decode cache (cache_len = sb + max_gen) and match
    its solo unpadded run."""
    cfg = FAMILY_CONFIGS["dense"]
    params = init_params(KEY, cfg)
    eng = ServingEngine(
        params, cfg, max_gen=8, max_batch=2, max_wait=0.0,
        batch_buckets=(1, 2), seq_buckets=(SB,),
    )
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, SB)  # prompt_len == seq bucket
    assert len(prompt) == SB
    uid = eng.submit(prompt, max_new_tokens=eng.max_gen, now=0.0)
    got = eng.flush()[uid]
    assert got.shape == (eng.max_gen,)
    np.testing.assert_array_equal(
        got, _solo_tokens(params, cfg, prompt, eng.max_gen)
    )


def test_submit_rejects_empty_prompt(env):
    eng = ServingEngine(
        env["params"], MODEL, max_gen=4, max_batch=2, max_wait=0.0,
        batch_buckets=(1, 2), seq_buckets=(SB,),
    )
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros((0,), np.int32), now=0.0)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([], now=0.0)
    assert eng.scheduler.n_pending == 0  # nothing half-enqueued


def test_digital_engine_and_tier_energy_accounting(env):
    """analog_cfg=None serves the digital model: K is a no-op there, so
    mixed-K submissions coalesce into one batch; submissions above max_gen
    are rejected (no silent clamp); uid results cover every request."""
    eng = ServingEngine(
        env["params"], MODEL, max_gen=4, max_batch=2, max_wait=0.0,
        batch_buckets=(1, 2), seq_buckets=(SB,),
    )
    with pytest.raises(ValueError, match="max_gen"):
        eng.submit(np.arange(10) % MODEL.vocab_size, max_new_tokens=99, now=0.0)
    u0 = eng.submit(np.arange(10) % MODEL.vocab_size, max_new_tokens=4, now=0.0)
    u1 = eng.submit(np.arange(4) % MODEL.vocab_size, n_repeats=4,
                    max_new_tokens=2, now=0.0)
    out = eng.flush()
    assert set(out) == {u0, u1}
    assert out[u0].shape == (4,) and out[u1].shape == (2,)
    assert eng.stats["batches"] == 1  # digital mode never splits on K


def test_engine_rejects_mixed_clock_domains(env):
    eng = ServingEngine(
        env["params"], MODEL, max_gen=4, max_batch=2, max_wait=0.0,
        batch_buckets=(1, 2), seq_buckets=(SB,),
    )
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(np.arange(4), max_new_tokens=0, now=0.0)
    with pytest.raises(ValueError, match="n_repeats"):
        eng.submit(np.arange(4), n_repeats=0, now=0.0)
    eng.submit(np.arange(4) % MODEL.vocab_size, now=0.0)  # virtual clock
    with pytest.raises(ValueError, match="clock"):
        eng.poll()  # real clock with requests pending: deadlines undefined
    assert eng.flush()  # flush ignores deadlines and drains fine


def test_engine_repins_clock_when_drained(env):
    """A fully drained engine holds no arrival timestamps, so it may switch
    clock domains: finish a virtual-time replay, then serve live (and back)."""
    eng = ServingEngine(
        env["params"], MODEL, max_gen=4, max_batch=2, max_wait=0.0,
        batch_buckets=(1, 2), seq_buckets=(SB,),
    )
    prompt = np.arange(6) % MODEL.vocab_size
    u0 = eng.submit(prompt, now=0.0)  # pins the virtual clock
    assert u0 in eng.flush()  # drained: n_pending == 0
    u1 = eng.submit(prompt)  # re-pins to the real clock
    assert u1 in eng.poll()
    u2 = eng.submit(prompt, now=5.0)  # drained again: back to virtual
    with pytest.raises(ValueError, match="clock"):
        eng.poll()  # pending request: the mixed-clock guard still holds
    assert u2 in eng.flush()
