"""Tensor-parallel serving goldens: the tile-salted noise contract.

Each TP shard of the column-parallel analog matmul salts its counter-based
noise stream with its *global* tile coordinates, so shard (i, j) draws
exactly the (i, j) tile of the unsharded stream — sharding can never change
which noise a request sees. Pinned here at three levels:

  * unit: ``kernels/prng.gaussian_tile`` at offset (r0, c0) is bit-exactly
    the [r0:, c0:] slice of the offset-(0, 0) draw (single and K-repeat
    streams), and column shards partition the full draw.
  * golden: a mesh-attached ``ServingEngine`` serves bit-identical tokens
    to the single-device oracle, per family (dense + griffin, the stateful
    rung) and under a *non-uniform* per-layer ``PrecisionProfile``.

The mesh goldens need multiple host devices; they skip unless the process
was launched with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the CI sharded job / README recipe). The unit test always runs.
"""
import jax
import numpy as np
import pytest

from repro.core import AnalogConfig, PrecisionProfile
from repro.kernels import prng
from repro.launch.mesh import make_mesh_for_devices
from repro.models import init_energy_tree, init_params
from repro.models.config import ModelConfig
from repro.serving import ServingEngine
from test_serving import ENERGY_AJ, SB

KEY = jax.random.PRNGKey(0)

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices; run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

DENSE = ModelConfig(
    name="shard-dense", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=1, d_ff=64, vocab_size=128, attn_q_chunk=16, attn_kv_chunk=16,
    loss_chunk=32, dtype="float32",
)
GRIFFIN = ModelConfig(
    name="shard-griffin", family="griffin", n_layers=3, d_model=32, n_heads=2,
    n_kv_heads=1, head_dim=16, d_ff=64, vocab_size=128, rnn_width=32,
    conv_width=4, local_window=8, attn_q_chunk=16, attn_kv_chunk=16,
    loss_chunk=32, dtype="float32",
)

#: non-uniform per-layer repeat profiles (n_layers entries each)
PROFILES = {"shard-dense": (2, 1), "shard-griffin": (2, 1, 1)}


# --------------------------------------------------------------------------
# unit: tile-coordinate noise salt
# --------------------------------------------------------------------------


def test_tile_salt_shard_equals_slice():
    """Shard (i, j) of the sharded draw IS slice (i, j) of the unsharded
    draw — ``gaussian_tile`` is a pure function of global element indices."""
    k0, k1 = np.uint32(0xA5A5_A5A5), np.uint32(0x1234)
    full = np.asarray(prng.gaussian_tile(k0, k1, 0, 0, (16, 24)))
    for r0, c0, m, n in [(0, 0, 16, 24), (4, 8, 8, 8), (12, 16, 4, 8)]:
        tile = np.asarray(prng.gaussian_tile(k0, k1, r0, c0, (m, n)))
        np.testing.assert_array_equal(tile, full[r0 : r0 + m, c0 : c0 + n])
    # the K-repeat averaged stream tiles the same way (fused kernel path)
    full_k = np.asarray(
        prng.repeat_averaged_gaussian_tile(k0, k1, 0, 0, (16, 24), 3)
    )
    tile_k = np.asarray(
        prng.repeat_averaged_gaussian_tile(k0, k1, 4, 8, (8, 8), 3)
    )
    np.testing.assert_array_equal(tile_k, full_k[4:12, 8:16])
    # column shards partition the full draw exactly — the TP contract:
    # shard j at col0 = j * n_local reconstructs the unsharded stream
    shards = [
        np.asarray(prng.gaussian_tile(k0, k1, 0, j * 12, (16, 12)))
        for j in range(2)
    ]
    np.testing.assert_array_equal(np.concatenate(shards, axis=1), full)
    # and the weight-noise stream (its own salted key) tiles identically
    wk0 = k0 ^ np.uint32(prng.WEIGHT_STREAM_SALT)
    w_full = np.asarray(prng.gaussian_tile(wk0, k1, 0, 0, (8, 16)))
    w_tile = np.asarray(prng.gaussian_tile(wk0, k1, 0, 8, (8, 8)))
    np.testing.assert_array_equal(w_tile, w_full[:, 8:16])


# --------------------------------------------------------------------------
# golden: sharded engine == single-device oracle, per family
# --------------------------------------------------------------------------


def _serve_tokens(cfg, env, mesh):
    """Serve a fixed trace (uniform K=2 + the non-uniform profile tier,
    explicit per-request noise keys) and return uid -> tokens."""
    profile = PrecisionProfile(PROFILES[cfg.name], name="nu")
    eng = ServingEngine(
        env["params"], cfg, analog_cfg=AnalogConfig.shot(backend="tile"),
        energies=env["energies"], max_gen=4, max_batch=2, max_wait=0.0,
        batch_buckets=(1, 2), seq_buckets=(SB,), k_ladder=(1, 2),
        profiles=[profile], mesh=mesh,
    )
    rng = np.random.default_rng(7)
    out = {}
    for i, tier in enumerate([2, "nu", "nu", 2]):
        prompt = rng.integers(0, cfg.vocab_size, 6 + 3 * i).astype(np.int32)
        uid = eng.submit(
            prompt, tier=tier, max_new_tokens=3,
            key=jax.random.fold_in(KEY, 100 + i),
        )
        out[i] = uid
    results = eng.flush()
    return {i: np.asarray(results[uid]) for i, uid in out.items()}


@needs_mesh
@pytest.mark.parametrize("cfg", [DENSE, GRIFFIN], ids=lambda c: c.family)
def test_sharded_tokens_match_unsharded_oracle(cfg):
    env = dict(
        params=init_params(KEY, cfg),
        energies=init_energy_tree(cfg, ENERGY_AJ),
    )
    oracle = _serve_tokens(cfg, env, mesh=None)
    mp = 2 if jax.device_count() < 4 else 4
    mesh = make_mesh_for_devices(mp, model_parallel=mp)
    sharded = _serve_tokens(cfg, env, mesh=mesh)
    assert set(sharded) == set(oracle)
    for i in oracle:
        np.testing.assert_array_equal(sharded[i], oracle[i]), i
