"""Shape-aware logical sharding resolution (pure logic, no devices needed
beyond the local mesh)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.sharding import DEFAULT_RULES, DP_RULES, spec, use_mesh, zero1_axes


class FakeMesh:
    """Duck-typed mesh: (data=16, model=16)."""

    axis_names = ("data", "model")

    class _Dev:
        shape = (16, 16)

    devices = _Dev()
    size = 256


MESH = FakeMesh()


def _spec(names, shape, rules=DEFAULT_RULES):
    return spec(names, rules=rules, mesh=MESH, shape=shape)


def test_divisible_dims_shard():
    assert _spec((None, "mlp"), (4096, 12800)) == P(None, "model")
    assert _spec(("vocab", None), (49168, 4096)) == P("model", None)


def test_non_divisible_dims_fall_back_to_replicated():
    # 92553 % 16 != 0 -> no vocab sharding
    assert _spec(("vocab", None), (92553, 2048)) == P(None, None)


def test_conflict_resolution_first_dim_wins():
    # llama4 expert weights: experts take "data"; expert_embed would also
    # want "data" -> dropped; expert_mlp gets "model"
    s = _spec(("layers", "experts", "expert_embed", "expert_mlp"), (24, 128, 5120, 8192))
    assert s == P(None, "data", None, "model")


def test_grok_virtual_expert_fallback():
    # 8 experts cannot shard 16-way -> d_model picks up "data" (2D expert TP)
    s = _spec(("layers", "experts", "expert_embed", "expert_mlp"), (64, 8, 6144, 32768))
    assert s == P(None, None, "data", "model")


def test_tuple_axes_degrade_to_prefix():
    # batch 8 with ("pod","data") on a single-pod mesh -> "data" (8 % 16 != 0
    # fails, but there is no pod axis so candidates = ("data",) and 8 % 16
    # fails -> None)
    assert _spec(("batch", None), (8, 128)) == P(None, None)
    assert _spec(("batch", None), (256, 128)) == P("data", None)


def test_dp_rules_put_everything_on_batch():
    assert _spec(("batch", None, None), (256, 4096, 2048), rules=DP_RULES) == P(
        ("data", "model"), None, None
    )
    assert _spec((None, "mlp"), (2048, 8192), rules=DP_RULES) == P(None, None)


def test_zero1_axes_targets_first_replicated_dim():
    assert zero1_axes(("layers", None, "mlp")) == ("layers", "zero", "mlp")
    assert zero1_axes(("vocab", None)) == ("vocab", "zero")
    # fully-sharded params gain nothing
    assert zero1_axes(("layers", "experts", "expert_embed", "expert_mlp")) == (
        "layers", "experts", "expert_embed", "expert_mlp",
    )


def test_without_shape_no_filtering():
    assert spec(("vocab",), rules=DEFAULT_RULES, mesh=MESH) == P("model")
