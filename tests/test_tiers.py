"""Pluggable execution tiers (serving/tiers.py): the ExecutionTier
interface, the engine-owned TierRegistry, first-class int8 digital tiers
served next to analog tiers in one engine, the fault-retry / drift / SLA
promotion-demotion ladders resolving through the registry, the streaming
MetricsFeed, and the lint contract that no tier-kind branching survives
outside tiers.py."""
import json
import os
import re

import jax
import numpy as np
import pytest

from repro.core import (
    DIGITAL_INT8_AJ_PER_MAC,
    AnalogConfig,
    PrecisionProfile,
    total_macs,
)
from repro.models import init_energy_tree, init_params, lm
from repro.models.config import ModelConfig
from repro.serving import (
    AnalogProfileTier,
    DigitalTier,
    DriftEvent,
    ExecutionTier,
    FaultPlan,
    Int8DigitalTier,
    MetricsFeed,
    PolicyConfig,
    PrecisionGovernor,
    ServingEngine,
    TierSpec,
    UniformKTier,
)
from test_serving import ENERGY_AJ, SB

KEY = jax.random.PRNGKey(0)
MODEL = ModelConfig(
    name="tier-test", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=1, d_ff=64, vocab_size=128, attn_q_chunk=16, attn_kv_chunk=16,
    loss_chunk=32, dtype="float32",
)


@pytest.fixture(scope="module")
def env():
    params = init_params(KEY, MODEL)
    energies = init_energy_tree(MODEL, ENERGY_AJ)
    return dict(params=params, energies=energies)


def _engine(env, *, analog=True, **kw):
    extra = {}
    if analog:
        extra = dict(analog_cfg=AnalogConfig.shot(), energies=env["energies"])
    kw.setdefault("max_gen", 8)
    kw.setdefault("max_wait", 0.0)
    kw.setdefault("max_batch", 4)
    return ServingEngine(
        env["params"], MODEL,
        batch_buckets=(1, 2, 4), seq_buckets=(SB,),
        k_ladder=(1, 2, 4), **extra, **kw,
    )


def _prompts(n, seed=3, lens=(7, 19, 28)):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 128, lens[i % len(lens)]).astype(np.int32)
            for i in range(n)]


def _drain(eng, t=0.0, dt=0.01, max_iters=400):
    results = {}
    for _ in range(max_iters):
        if not eng.n_in_flight:
            break
        t += dt
        results.update(eng.pump_step(now=t))
    assert not eng.n_in_flight, "engine failed to drain (hang)"
    return results, t


# --------------------------------------------------------------------------
# lint: tier-kind branching must not survive outside tiers.py
# --------------------------------------------------------------------------


def test_no_tier_key_branches():
    """No serving module besides tiers.py may construct tier cache keys,
    branch on the digital sentinel, or price uniform K directly — the
    whole point of the ExecutionTier interface is that those live in
    exactly one place."""
    serving_dir = os.path.join(
        os.path.dirname(__file__), "..", "src", "repro", "serving"
    )
    banned = ('("digital"', "cache_key(", "PrecisionProfile.uniform(",
              "is_uniform")
    offenders = []
    for fname in sorted(os.listdir(serving_dir)):
        if not fname.endswith(".py") or fname == "tiers.py":
            continue
        text = open(os.path.join(serving_dir, fname)).read()
        # strings inside comments don't construct keys; strip them so docs
        # may still *mention* the interface
        code = "\n".join(re.sub(r"#.*", "", ln) for ln in text.splitlines())
        for pat in banned:
            if pat in code:
                offenders.append((fname, pat))
    assert not offenders, (
        f"tier-kind branching leaked outside serving/tiers.py: {offenders}"
    )


# --------------------------------------------------------------------------
# registry: registration, resolution, identity
# --------------------------------------------------------------------------


def test_registry_lazy_uniform_and_cache_key(env):
    eng = _engine(env)
    t = eng.tiers.get(2)
    assert isinstance(t, UniformKTier) and t.k == 2
    assert eng.tiers.get(2) is t  # memoized
    # executable identity: K + backend + noise kind, nothing else
    assert t.cache_key() == (2, eng.analog_cfg.backend, eng.analog_cfg.noise.kind)
    key = eng.tiers.exe_key("decode", 2, 4, 40)
    assert key == ("decode", 4, 40) + t.cache_key()
    # the shared admission insert is tier-free
    assert eng.tiers.exe_key("insert", None, 4, 40, 2) == ("insert", 4, 40, 2)


def test_registry_register_is_add_only(env):
    eng = _engine(env)
    tier = Int8DigitalTier()
    assert eng.register_tier(tier) == "int8"
    assert eng.register_tier(tier) == "int8"  # same object: idempotent
    with pytest.raises(ValueError, match="frozen"):
        eng.register_tier(Int8DigitalTier())  # same id, different object
    with pytest.raises(TypeError, match="ExecutionTier"):
        eng.tiers.register("not-a-tier")
    # a profile may not shadow a registered tier id
    with pytest.raises(ValueError, match="frozen"):
        eng.register_profile(PrecisionProfile((2, 1), name="int8"))


def test_registry_resolution_forms(env):
    eng = _engine(env)
    prof = PrecisionProfile((2, 1), name="mix")
    assert eng.tiers.resolve(prof) == "mix"  # auto-registered
    assert eng.tiers.resolve(3) == 3
    tier = Int8DigitalTier(tier_id="q8")
    assert eng.tiers.resolve(tier) == "q8"
    # degenerate uniform+coalesce profile shares the bare-K tier
    assert eng.tiers.resolve_profile(
        PrecisionProfile.uniform(2, MODEL.n_layers)
    ) == 2
    with pytest.raises(ValueError, match="unknown profile"):
        eng.tiers.get("never-registered")


def test_profile_tier_shares_executables_with_uniform_k(env):
    """A uniform+coalesce profile and the bare-K tier have EQUAL cache
    keys: equal schedule => shared warm executables, by construction."""
    eng = _engine(env)
    u = PrecisionProfile.uniform(2, MODEL.n_layers)
    eng.register_profile(u)
    assert (eng.tiers.get(u.name).cache_key()
            == eng.tiers.get(2).cache_key())


def test_tier_binding_is_exclusive(env):
    eng_a = _engine(env)
    eng_b = _engine(env)
    tier = Int8DigitalTier()
    eng_a.register_tier(tier)
    with pytest.raises(ValueError, match="another engine"):
        eng_b.register_tier(tier)
    unbound = DigitalTier(tier_id="loose")
    with pytest.raises(ValueError, match="not registered"):
        unbound.engine


def test_registry_ladder_and_exemptions(env):
    eng = _engine(env)
    eng.register_tier(UniformKTier(1, accuracy=0.8))
    eng.register_tier(UniformKTier(4, accuracy=0.97))
    eng.register_tier(Int8DigitalTier())  # accuracy 1.0, drift exempt
    ladder = eng.tiers.ladder()
    assert [t.tier_id for t in ladder] == [1, 4, "int8"]  # floor-ordered
    assert eng.tiers.drift_exempt_ids() == ["int8"]


# --------------------------------------------------------------------------
# degradation ladder: promote() per tier kind
# --------------------------------------------------------------------------


def test_uniform_promote_climbs_and_saturates(env):
    eng = _engine(env)
    assert eng.tiers.get(1).promote() == 2
    assert eng.tiers.get(2).promote() == 4
    assert eng.tiers.get(4).promote() == 4  # calibrated top: saturates
    assert eng.tiers.get(3).promote() == 4  # off-ladder K climbs onto it


def test_profile_promote_prefers_registered_higher_accuracy_tier(env):
    eng = _engine(env)
    prof = PrecisionProfile((2, 1), name="lo", accuracy=0.9)
    eng.register_profile(prof)
    hi = PrecisionProfile((4, 2), name="hi", accuracy=0.97)
    eng.register_profile(hi)
    # the registered higher-accuracy tier wins: its executables are warm
    assert eng.tiers.get("lo").promote() == "hi"


def test_profile_promote_retrims_when_nothing_higher_registered(env):
    eng = _engine(env)
    prof = PrecisionProfile((2, 1), name="solo")
    eng.register_profile(prof)
    promoted = eng.tiers.get("solo").promote()
    assert promoted == "solo+retrim"
    assert eng.tiers.profiles["solo+retrim"].repeats == (4, 2)
    # saturated profile promotes to itself (never invents K > ladder top)
    top = PrecisionProfile((4, 4), name="top")
    eng.register_profile(top)
    assert eng.tiers.get("top").promote() == "top"


def test_digital_promote_is_identity(env):
    eng = _engine(env)
    eng.register_tier(Int8DigitalTier())
    assert eng.tiers.get("int8").promote() == "int8"
    assert eng.tiers.get("int8").drift_promote() == "int8"
    # drift response passes profiles through unchanged (old behavior)
    eng.register_profile(PrecisionProfile((2, 1), name="p"))
    assert eng.tiers.drift_promote("p") == "p"
    assert eng.tiers.drift_promote(1) == 2  # uniform K rides the ladder


def test_fault_retry_promotes_profile_requests_through_registry(env):
    """Satellite: a faulted profile-tier request retries at a genuinely
    higher-precision tier (here the per-layer re-trim), not a silent
    uniform-K fallback."""
    plan = FaultPlan(exe_faults=[("decode", 2)])
    eng = _engine(env, continuous=True, pool_slots=2, fault_plan=plan)
    eng.register_profile(PrecisionProfile((2, 1), name="mix"))
    uids = [eng.submit(p, profile="mix", max_new_tokens=4, now=0.0)
            for p in _prompts(2)]
    results, _ = _drain(eng)
    assert eng.stats["exe_faults"] >= 1 and eng.stats["retried"] >= 1
    entry = next(e for e in eng.fault_log if e["kind"] == "exe_fault")
    for u in entry["retried"]:
        assert entry["promoted"][u] == "mix+retrim"
        assert eng.served_tiers[u] == "mix+retrim"
    assert all(isinstance(results[u], np.ndarray) for u in uids)
    assert eng.tiers.profiles["mix+retrim"].repeats == (4, 2)


# --------------------------------------------------------------------------
# int8 digital through the continuous pool: bit-identity + zero retraces
# --------------------------------------------------------------------------


def test_int8_through_continuous_pool_golden(env):
    eng = _engine(env, continuous=True, pool_slots=4)
    eng.register_tier(Int8DigitalTier())
    prompts = _prompts(6, seed=11)
    keys = [jax.random.fold_in(jax.random.PRNGKey(5), i)
            for i in range(len(prompts))]
    pooled = {}
    for replay in range(2):  # replay 0 warms up compiles
        if replay == 1:
            eng.exe_cache.reset_stats()
            traces_before = eng.trace_count
        uids = [eng.submit(p, tier="int8", max_new_tokens=4, key=k, now=0.0)
                for p, k in zip(prompts, keys)]
        res, _ = _drain(eng)
        out = [res[u] for u in uids]
        if pooled:
            assert all(np.array_equal(a, b) for a, b in zip(out, pooled))
        pooled = out
    assert eng.trace_count == traces_before, "int8 pool re-traced"
    assert eng.exe_cache.stats()["hit_rate"] == 1.0
    # solo re-serve through the same pool: identical tokens per request
    for i in (0, 3, 5):
        uid = eng.submit(prompts[i], tier="int8", max_new_tokens=4,
                         key=keys[i], now=0.0)
        solo = _drain(eng)[0][uid]
        assert np.array_equal(solo, pooled[i]), i
    # int8 is deterministic digital execution: no noise, key-independent
    uid = eng.submit(prompts[0], tier="int8", max_new_tokens=4,
                     key=jax.random.PRNGKey(999), now=0.0)
    assert np.array_equal(_drain(eng)[0][uid], pooled[0])


def test_analog_and_digital_tiers_share_one_engine(env):
    """Mixed-traffic golden: uniform-K analog, profile analog, and int8
    digital serve side by side — per-tier token accounting holds and each
    tier's energy comes from its own honest cost model."""
    eng = _engine(env, continuous=True, pool_slots=4,
                  profiles=[PrecisionProfile((2, 1), name="mix")])
    eng.register_tier(Int8DigitalTier())
    tiers = [1, "mix", "int8", 1, "mix", "int8"]
    prompts = _prompts(len(tiers), seed=7)
    uids = {}
    for i, (p, t) in enumerate(zip(prompts, tiers)):
        uids[i] = eng.submit(p, tier=t, max_new_tokens=4, now=0.0)
    results, _ = _drain(eng)
    assert all(isinstance(results[u], np.ndarray) for u in uids.values())
    for i, t in enumerate(tiers):
        assert eng.served_tiers[uids[i]] == t
    toks = eng.stats["tier_tokens"]
    assert toks[1] == toks["mix"] == toks["int8"] == 8  # 2 requests x 4
    # honest economics: the digital tier prices from the per-MAC digital
    # model — never the analog energy tree
    macs = float(total_macs(lm.energy_macs(MODEL, 1)))
    assert eng.tier_energy_per_token("int8") == pytest.approx(
        DIGITAL_INT8_AJ_PER_MAC * macs
    )
    e1 = eng.tier_energy_per_token(1)
    e_mix = eng.tier_energy_per_token("mix")
    e4 = eng.tier_energy_per_token(4)
    assert e1 < e_mix < e4 < eng.tier_energy_per_token("int8")


def test_submit_tier_kwarg_is_exclusive(env):
    eng = _engine(env)
    eng.register_tier(Int8DigitalTier())
    with pytest.raises(ValueError, match="not both"):
        eng.submit(_prompts(1)[0], tier="int8", n_repeats=2, now=0.0)
    with pytest.raises(ValueError, match="not both"):
        eng.submit(_prompts(1)[0], tier="int8", profile="x", now=0.0)


# --------------------------------------------------------------------------
# SLA governor: cross-domain demotion through the registry ladder
# --------------------------------------------------------------------------


def test_governor_demotes_across_domains_to_digital(env):
    """Under overload the governor may demote floorless analog traffic
    onto a cheaper *digital* tier — the ladder is one floor-ordered table
    spanning both domains, resolved through the TierRegistry."""
    eng = _engine(env, continuous=True, pool_slots=2, max_batch=4)
    # an int8 accelerator priced BELOW every analog rung (explicit
    # per-MAC cost: the honest-model plumbing is what's under test, not
    # the physical constant)
    eng.register_tier(Int8DigitalTier(aj_per_mac=1.0))
    policy = PolicyConfig(
        tiers=(TierSpec(1, 0.8), TierSpec(2, 0.9), TierSpec(4, 0.97),
               TierSpec("int8", 1.0)),
        demote_at=1.0, promote_at=0.25, shed_at=6.0, min_dwell=2,
    )
    eng.governor = PrecisionGovernor(eng, policy)
    assert [row[2] for row in eng.governor._table][0] == "int8"  # cheapest
    uids = [eng.submit(p, n_repeats=4, now=0.0, max_new_tokens=4,
                       target_latency=5.0)
            for p in _prompts(9)]
    results, _ = _drain(eng)
    assert eng.stats["demoted"] > 0
    assert all(isinstance(results[u], np.ndarray) for u in uids)
    served = {eng.served_tiers[u] for u in uids}
    assert "int8" in served, f"no request crossed domains: {served}"


def test_governor_rejects_unregistered_policy_tier(env):
    eng_kw = dict(continuous=True, pool_slots=2)
    with pytest.raises(ValueError, match="registered"):
        _engine(env, policy=PolicyConfig(
            tiers=(TierSpec(1, 0.8), TierSpec("ghost", 0.9))), **eng_kw)


# --------------------------------------------------------------------------
# drift response: digital tiers are exempt
# --------------------------------------------------------------------------


def test_drift_promotion_skips_digital_tiers(env):
    eng = _engine(env, continuous=True, pool_slots=2)
    eng.register_tier(Int8DigitalTier())
    evt = DriftEvent(step=0, probe_idx=0, estimate=1.8, band=(0.8, 1.2))
    eng.promote_tiers(evt)
    assert eng.promoted
    entry = next(e for e in eng.fault_log if e["kind"] == "drift_promotion")
    assert entry["exempt_tiers"] == ["int8"]
    # new uniform-K traffic promotes one rung; int8 serves unpromoted
    u_k = eng.submit(_prompts(1)[0], n_repeats=1, max_new_tokens=2, now=0.0)
    u_d = eng.submit(_prompts(1)[0], tier="int8", max_new_tokens=2, now=0.0)
    _drain(eng)
    assert eng.served_tiers[u_k] == 2
    assert eng.served_tiers[u_d] == "int8"


# --------------------------------------------------------------------------
# MetricsFeed: ring bound, JSONL sink, per-tier series
# --------------------------------------------------------------------------


def test_metrics_feed_samples_and_jsonl(env, tmp_path):
    sink = tmp_path / "metrics.jsonl"
    feed = MetricsFeed(capacity=8, jsonl_path=sink)
    eng = _engine(env, continuous=True, pool_slots=2, metrics=feed)
    eng.register_tier(Int8DigitalTier())
    for i, p in enumerate(_prompts(4)):
        eng.submit(p, tier="int8" if i % 2 else 1, max_new_tokens=3,
                   now=i * 1e-3)
    _drain(eng)
    feed.close()
    assert 0 < len(feed) <= 8  # ring bound holds
    lines = [json.loads(ln) for ln in sink.read_text().splitlines()]
    assert len(lines) >= len(feed)  # sink kept what the ring evicted
    last = feed.samples()[-1]
    assert last["tiers"]["int8"]["drift_exempt"] is True
    assert last["tiers"]["1"]["drift_exempt"] is False
    assert last["tiers"]["int8"]["tokens"] == 6  # 2 requests x 3
    assert last["tiers"]["int8"]["energy_per_token_aj"] > 0
    assert last["queue_depth"] == 0 and last["traces"] == eng.trace_count
    series = feed.tier_series("tokens")
    assert series["1"][-1] == 6 and series["int8"][-1] == 6
    feed.note_drift(1.3)
    s = feed.record(eng, now=1.0)
    assert s["drift_estimate"] == 1.3
    with pytest.raises(ValueError, match="capacity"):
        MetricsFeed(capacity=0)
